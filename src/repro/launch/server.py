"""HTTP/SSE serving entrypoint — the asyncio front-end over the engines.

    # encoder serving (JSON request/response) on the golden plan
    PYTHONPATH=src python -m repro.launch.server --arch bert-base \
        --task tnews --plan tests/data/golden_plan.json --port 8080

    # a decode-capable arch mounts BOTH endpoints: /v1/encode for the
    # encoder task and /v1/generate for SSE token streaming
    PYTHONPATH=src python -m repro.launch.server --arch qwen2-0.5b \
        --task tnews --policy ffn --port 8080

    # input-adaptive precision: per-cluster plans, routed per request
    # (docs/adaptive-precision.md; tag requests with X-SAMP-Traffic-Class
    # or the 'traffic_class' JSON field for task: routing)
    ... --clusters length:8,16

    curl -s localhost:8080/v1/encode -d '{"tokens": [2, 17, 9, 41]}'
    curl -sN localhost:8080/v1/generate -d '{"prompt": [2, 17], "max_tokens": 8}'
    curl -s localhost:8080/metrics

Builds the model exactly like ``launch/serve.py`` (same shared flag
surface — ``launch/cli.py``), wraps the engine(s) in
:class:`~repro.serve.frontend.HTTPFrontend`, and serves until SIGTERM /
SIGINT, which triggers a graceful drain (stop admitting with 503, finish
in-flight requests, exit). ``--port 0`` binds an ephemeral port and
prints it — CI's smoke uses that. See docs/http-serving.md for the
endpoint contracts, backpressure semantics, and the metrics catalog.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import make_task
from repro.launch.cli import (add_serving_flags, parse_cluster_model,
                              resolve_task)
from repro.launch.mesh import make_serving_mesh
from repro.launch.serve import build_model, build_routed_model
from repro.serve import EncoderServeEngine, ServeEngine
from repro.serve.frontend import HTTPFrontend
from repro.toolkit.registry import get_target
from repro.toolkit.targets import TARGET_FOR_TASK_KIND


def build_frontend(args, *, log=print) -> HTTPFrontend:
    """Build engine(s) for the requested deployment and mount them.

    ``--task lm`` mounts the decode engine only. An encoder task on a
    decode-capable arch mounts BOTH engines over one param tree (the cls
    head rides next to the tied-embedding lm head), so a single server
    answers /v1/encode and /v1/generate.
    """
    cfg = get_config(args.arch).reduced()
    task_name = resolve_task(cfg, args.task)
    mesh = make_serving_mesh(args.mesh)
    cluster_model = parse_cluster_model(args.clusters)
    encoder = decode = None
    decode_router = None
    if task_name == "lm":
        if cluster_model is not None:
            decode_router, entry = build_routed_model(
                cfg, args.policy, cluster_model, seed=args.seed,
                plan_file=args.plan, max_len=args.max_len, log=log)
            params, plan, precision = (entry.params, entry.plan,
                                       entry.precision)
        else:
            params, plan, precision = build_model(
                cfg, args.policy, seed=args.seed, plan_file=args.plan,
                strategy=args.strategy, max_latency=args.max_latency,
                log=log)
    else:
        task = make_task(task_name, vocab_size=cfg.vocab_size,
                         seq_len=args.max_len)
        spec = get_target(TARGET_FOR_TASK_KIND[task.kind])
        head_kind = "ner" if spec.token_level else "cls"
        head = (head_kind, max(task.n_classes, 1))
        router = None
        if cluster_model is not None:
            # a PlanRouter binds to ONE runtime: route the encoder (the
            # served task); a co-mounted decode engine serves the default
            # member unrouted
            router, entry = build_routed_model(
                cfg, args.policy, cluster_model, seed=args.seed, head=head,
                plan_file=args.plan, max_len=args.max_len, log=log)
            params, plan, precision = (entry.params, entry.plan,
                                       entry.precision)
        else:
            params, plan, precision = build_model(
                cfg, args.policy, seed=args.seed, head=head,
                plan_file=args.plan, strategy=args.strategy,
                max_latency=args.max_latency, log=log)
        encoder = EncoderServeEngine(cfg, params, plan, target=spec,
                                     max_batch=args.slots,
                                     max_wait=args.max_wait,
                                     max_len=args.max_len,
                                     backend=args.backend, mesh=mesh,
                                     router=router)
    if cfg.supports_decode:
        decode = ServeEngine(cfg, params, plan, batch_slots=args.slots,
                             max_len=args.max_len, seed=args.seed,
                             cache_dtype=jnp.float32,
                             backend=args.backend, mesh=mesh,
                             page_size=args.page_size,
                             kv_cache=args.kv_dtype, precision=precision,
                             router=decode_router)
    return HTTPFrontend(encoder=encoder, decode=decode, host=args.host,
                        port=args.port, max_pending=args.max_pending,
                        default_deadline_s=args.deadline_s, log=log)


def main():
    ap = add_serving_flags(argparse.ArgumentParser())
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 binds an ephemeral port (printed at startup)")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="admission bound on in-flight requests; overflow "
                         "answers 429 + Retry-After")
    ap.add_argument("--max-wait", type=float, default=0.005,
                    help="encoder micro-batch ageing window (seconds)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline when the request "
                         "states no deadline_ms (None = unbounded)")
    args = ap.parse_args()
    frontend = build_frontend(args)
    frontend.run_forever()


if __name__ == "__main__":
    main()
