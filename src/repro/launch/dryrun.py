import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST run before any other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs and record the roofline raw material.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves sharding rules (repro.distributed.sharding.Rules),
  3. lowers the cell's step function (train_step / prefill / serve_step)
     against abstract inputs — no arrays are ever allocated,
  4. ``.compile()``s it (this is the proof the distribution config is
     coherent: sharding mismatches, OOM-at-compile and unsupported
     collectives all fail here),
  5. records ``memory_analysis()``, ``cost_analysis()`` and the per-type
     collective bytes parsed from the post-SPMD optimized HLO,
  6. appends the cell's record to results/dryrun/<cell>.json (incremental:
     re-runs skip cells that already have results unless --force).

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --all --mesh single --policy ffn8
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core.precision import EncoderPolicy, LayerMode, make_policy
from repro.distributed.sharding import Rules
from repro.launch.hlo_cost import analyze_hlo
from repro.launch import shapes as SH
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.quant import ptq
from repro.train.optimizer import AdamW
from repro.train.trainer import TrainConfig, Trainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the post-SPMD HLO
    (per-device numbers — SPMD-partitioned shapes are local shapes)."""
    out = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op name appears after '=' as e.g. 'bf16[128,512]{1,0} all-gather('
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                m = _SHAPE_RE.search(stripped)
                if not m:
                    continue
                dtype, dims = m.group(1), m.group(2)
                nbytes = _DTYPE_BYTES.get(dtype, 4)
                numel = 1
                for d in dims.split(","):
                    if d:
                        numel *= int(d)
                out[c]["bytes"] += numel * nbytes
                out[c]["count"] += 1
                break
    return out


def abstract_stats(cfg) -> dict:
    """Placeholder per-layer amax stats (value 1.0) — scale values don't
    affect lowering/compile, only numerics."""
    sites = ("attn_in", "attn_out", "q", "k", "p", "v", "q_lat", "c_kv",
             "ffn_in", "ffn_hidden", "ffn_in_e", "shared_ffn_in",
             "shared_ffn_hidden", "rec_in", "rec_gate_in", "rec_out",
             "blk_in", "blk_conv_in", "blk_hidden", "qkv_in", "xm")
    return {f"layer{i}": {s: 1.0 for s in sites}
            for i in range(cfg.num_layers)}


def quantized_param_specs(cfg, policy, param_dtype=jnp.bfloat16):
    """Abstract quantized params: eval_shape the PTQ transform itself."""
    def build():
        params = T.init_params(jax.random.PRNGKey(0), cfg,
                               EncoderPolicy.full_float(cfg.num_layers),
                               dtype=param_dtype)
        qp, _ = ptq.apply_policy(params, cfg, policy, abstract_stats(cfg))
        return qp
    return jax.eval_shape(build)


def build_cell(arch: str, shape_name: str, mesh, policy_name: str = "float",
               param_dtype=jnp.bfloat16):
    """-> (jitted-with-shardings fn, example_args (SDS pytrees)).
    Raises ValueError for skipped cells."""
    cfg = get_config(arch)
    cell = SH.SHAPES[shape_name]
    ok, why = SH.cell_supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"SKIP {arch}/{shape_name}: {why}")
    policy = make_policy(cfg, policy_name)
    plan = T.build_plan(cfg, policy)
    # FSDP (ZeRO-3) for training only: a serving step must not all-gather
    # its weights every token — inference weights shard over 'model' and
    # replicate over 'data' (classic TP serving layout)
    rules = Rules(cfg, mesh, fsdp=(cell.kind == "train"))
    scheme = T.QuantScheme()
    head = None

    if policy_name == "float":
        params_sds = SH.params_specs(cfg, policy, param_dtype, head=head)
    else:
        params_sds = quantized_param_specs(cfg, policy, param_dtype)
    params_sh = rules.params_sharding(params_sds)
    batch_sds = SH.batch_specs(cfg, cell)
    batch_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), rules.batch_spec(batch_sds))

    if cell.kind == "train":
        trainer = Trainer(cfg, policy, mesh=mesh,
                          optimizer=AdamW(lr=1e-4),
                          tcfg=TrainConfig(remat=True,
                                           compute_dtype="bfloat16"),
                          scheme=scheme)
        step = trainer.make_step(jit=False)
        opt_sds = jax.eval_shape(trainer.optimizer.init, params_sds)
        opt_sh = jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s),
            rules.params_spec(opt_sds))
        fn = jax.jit(step,
                     in_shardings=(params_sh, opt_sh, None, batch_sh),
                     out_shardings=(params_sh, opt_sh, None, None))
        args = (params_sds, opt_sds, None, batch_sds)
        return fn, args

    caches_sds = SH.cache_specs(cfg, plan, cell)
    caches_sh = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), rules.cache_spec(caches_sds),
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    if cell.kind == "prefill":
        pchunk = rules.attn_chunk(cell.global_batch, cell.seq_len,
                                  cfg.num_heads)

        def step(params, batch, caches):
            return SH.prefill_step(params, batch, caches, cfg, plan, scheme,
                                   constrain=rules, chunk=pchunk)
        use_caches = cfg.supports_decode
        fn = jax.jit(step, in_shardings=(
            params_sh, batch_sh, caches_sh if use_caches else None))
        args = (params_sds, batch_sds, caches_sds if use_caches else None)
        return fn, args

    # decode
    def step(params, tokens, caches, pos):
        return T.decode_step(params, tokens, caches, pos, cfg, plan, scheme,
                             constrain=rules)
    tok_sds = batch_sds["tokens"]
    tok_sh = jax.NamedSharding(mesh, rules.batch_spec({"t": tok_sds})["t"])
    fn = jax.jit(step, in_shardings=(params_sh, tok_sh, caches_sh, None))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_sds, tok_sds, caches_sds, pos_sds)
    return fn, args


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             policy_name: str = "float", out_dir: str = RESULTS_DIR,
             force: bool = False) -> dict:
    cell_id = f"{arch}__{shape_name}__{mesh_kind}__{policy_name}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "policy": policy_name, "status": "error"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        fn, args = build_cell(arch, shape_name, mesh, policy_name)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        # trip-count-aware re-analysis (XLA cost_analysis counts each while
        # body once — see repro.launch.hlo_cost)
        corrected = analyze_hlo(hlo)
        record.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            num_devices=mesh.devices.size,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            cost={"flops": cost.get("flops", 0.0),
                  "bytes accessed": cost.get("bytes accessed", 0.0),
                  "transcendentals": cost.get("transcendentals", 0.0)},
            corrected={"flops": corrected["flops"],
                       "bytes": corrected["bytes"],
                       "collective_bytes": corrected["collective_bytes"]},
            collectives=corrected["collectives"],
            hlo_ops=len(hlo.splitlines()),
        )
    except ValueError as e:
        if str(e).startswith("SKIP"):
            record.update(status="skip", reason=str(e))
        else:
            record.update(status="error", error=str(e),
                          trace=traceback.format_exc()[-2000:])
    except Exception as e:  # compile failures are data, not crashes
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    record["wall_s"] = round(time.time() - t0, 1)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SH.SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="float")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = [a for a in ARCH_IDS if a != "bert-base"] \
        if args.arch is None else [args.arch]
    shapes = list(SH.SHAPES) if args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all and args.arch is None:
        ap.error("pass --arch or --all")

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.policy, args.out,
                               args.force)
                flops = rec.get("cost", {}).get("flops", 0)
                print(f"{arch:22s} {shape:12s} {mk:6s} {args.policy:6s} "
                      f"-> {rec['status']:5s} "
                      f"flops/dev={flops:.3e} wall={rec.get('wall_s')}s"
                      + (f"  ({rec.get('error', rec.get('reason', ''))})"
                         if rec["status"] != "ok" else ""),
                      flush=True)


if __name__ == "__main__":
    main()
