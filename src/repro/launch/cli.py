"""Shared CLI surface for the serving entrypoints.

``launch/serve.py`` (the synchronous one-shot CLI) and
``launch/server.py`` (the HTTP/SSE front-end) serve the same deployments,
so they must parse the same deployment flags the same way. This module is
the single definition of that surface — ``--arch / --task / --policy /
--plan / --clusters / --strategy / --max-latency / --backend / --mesh /
--slots / --max-len / --seed`` — so the two entrypoints cannot drift.
:func:`parse_cluster_model` turns the ``--clusters`` spec string into a
:class:`~repro.adaptive.clusters.ClusterModel`.
"""
from __future__ import annotations

import argparse


def add_serving_flags(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """The deployment flags every serving entrypoint shares."""
    ap.add_argument("--arch", required=True)
    ap.add_argument("--task", default=None,
                    help="lm (decode engine) | tnews|iflytek|afqmc|ner "
                         "(encoder engine); default: lm when the arch "
                         "decodes, tnews otherwise")
    ap.add_argument("--policy", default="float",
                    help="float | ffn[K] | full[K]")
    ap.add_argument("--plan", default=None,
                    help="path to a saved PrecisionPlan or PlanSet JSON "
                         "(overrides --policy/--strategy; a PlanSet needs "
                         "--clusters with a matching cluster count)")
    ap.add_argument("--clusters", default=None,
                    help="input-adaptive precision: route requests to "
                         "per-cluster plans. 'length:8,16' (length bins), "
                         "'task:chat,search' (X-SAMP-Traffic-Class "
                         "labels), 'kmeans:3' (embedding k-means). "
                         "Calibration turns cluster-conditional; --policy "
                         "deploys the same plan per cluster (per-cluster "
                         "scales), --plan may name a PlanSet")
    ap.add_argument("--strategy", default=None,
                    choices=("prefix_grid", "greedy", "latency_budget"),
                    help="pick the plan with a search strategy instead of "
                         "--policy")
    ap.add_argument("--max-latency", type=float, default=None,
                    help="latency ceiling (roofline seconds) for "
                         "--strategy latency_budget")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "fused", "auto"),
                    help="compute backend for quantized blocks: reference "
                         "XLA ops, fused Pallas kernels, or auto (fused on "
                         "TPU, reference elsewhere)")
    ap.add_argument("--mesh", default="1,1",
                    help="serving mesh as 'dp,tp' (data-parallel x tensor-"
                         "parallel device counts); 1,1 = unmeshed. Needs "
                         "dp*tp visible devices — on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots / encoder micro-batch size")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page; switches the decode caches "
                         "to the paged layout (pages allocated on demand, "
                         "freed on completion/cancel). Required for "
                         "--kv-dtype int8_*")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("float", "int8_per_head", "int8_per_token"),
                    help="KV-cache page scheme for every full-attention "
                         "layer; int8_per_head needs a plan calibrated "
                         "with KV stats, int8_per_token quantizes "
                         "dynamically at decode time. Default: the plan's "
                         "per-layer kv_cache schemes")
    return ap


def parse_cluster_model(spec):
    """Parse a ``--clusters`` spec into a ClusterModel (None -> None).

    ``length:8,16`` -> LengthBuckets((8, 16)); ``task:chat,search`` ->
    TaskLabel(("chat", "search")); ``kmeans:3`` -> EmbeddingKMeans(3).
    """
    if spec is None:
        return None
    from repro.adaptive import EmbeddingKMeans, LengthBuckets, TaskLabel
    kind, _, rest = spec.partition(":")
    try:
        if kind == "length":
            return LengthBuckets(tuple(int(x) for x in rest.split(",") if x))
        if kind == "task":
            return TaskLabel(tuple(x for x in rest.split(",") if x))
        if kind == "kmeans":
            return EmbeddingKMeans(int(rest))
    except (ValueError, TypeError) as e:
        raise SystemExit(f"--clusters {spec!r}: {e}")
    raise SystemExit(f"--clusters {spec!r}: unknown model {kind!r}; use "
                     f"length:<edges> | task:<labels> | kmeans:<K>")


def resolve_task(cfg, task):
    """Default/validate ``--task`` against the architecture: ``lm`` needs
    a decode-capable config; encoder-only configs default to ``tnews``."""
    if task is None:
        return "lm" if cfg.supports_decode else "tnews"
    if task == "lm" and not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: pass --task "
                         f"tnews|iflytek|afqmc|ner")
    return task
