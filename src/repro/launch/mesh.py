"""Production mesh construction (importing this module never touches jax
device state — the mesh is built lazily inside the function)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); multi-pod adds a leading pure-DP
    'pod' axis over DCN: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serving_mesh(spec: str):
    """Parse a serving CLI ``--mesh dp,tp`` spec into a (data, model) mesh.

    ``"2,1"`` = 2-way data parallel, ``"1,2"`` = 2-way tensor parallel,
    ``"4,2"`` = both. The product must not exceed the visible device count;
    on a CPU container grow it with the host-device trick
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    process starts). ``"1,1"`` returns ``None`` — the unmeshed single-
    device runtime, byte-identical to omitting ``--mesh``.
    """
    try:
        dp, tp = (int(p) for p in spec.split(","))
    except ValueError:
        raise ValueError(f"--mesh wants 'dp,tp' (two integers), got "
                         f"{spec!r}") from None
    if dp < 1 or tp < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    if dp == tp == 1:
        return None
    n = len(jax.devices())
    if dp * tp > n:
        raise ValueError(
            f"--mesh {spec} needs {dp * tp} devices but only {n} visible; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{dp * tp}")
    return jax.make_mesh((dp, tp), ("data", "model"))
