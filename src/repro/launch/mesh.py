"""Production mesh construction (importing this module never touches jax
device state — the mesh is built lazily inside the function)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod mesh: (data=16, model=16); multi-pod adds a leading pure-DP
    'pod' axis over DCN: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
