"""Serving launcher CLI: SAMP-quantized continuous-batching generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --policy ffn --requests 8 --max-tokens 16

Instantiates the reduced config (this is the CPU-container path; on TPU the
same flow runs the full config), PTQ-calibrates on synthetic batches,
applies the requested SAMP policy, and serves a batch of random-prompt
requests through the continuous-batching engine.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.precision import EncoderPolicy, make_policy
from repro.core.samp import SAMPEngine
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="float",
                    help="float | ffn[K] | full[K]")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(args.seed)
    eng = SAMPEngine(cfg, float_dtype="float32")
    params = T.init_params(key, cfg, eng.float_policy)

    policy = make_policy(cfg, args.policy)
    if policy.num_quant_ffn or policy.num_quant_mha:
        batches = [{"tokens": jax.random.randint(
            jax.random.PRNGKey(i), (2, 32), 0, cfg.vocab_size)}
            for i in range(4)]
        stats = eng.calibrate(params, batches)
        params, plan = eng.apply(params, stats, policy)
        print(f"[serve] applied SAMP policy: {policy.describe()}")
    else:
        plan = eng.float_plan

    server = ServeEngine(cfg, params, plan, batch_slots=args.slots,
                         max_len=args.max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(2, 9))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        server.submit(Request(uid=i, prompt=prompt,
                              max_tokens=args.max_tokens,
                              temperature=args.temperature))
    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    for req in sorted(done, key=lambda r: r.uid):
        print(f"  req{req.uid}: prompt={req.prompt} -> {req.output}")
    s = server.stats
    print(f"[serve] {s['retired']} requests, {s['tokens']} tokens in "
          f"{s['ticks']} ticks, {dt:.2f}s "
          f"({s['tokens'] / max(dt, 1e-9):.1f} tok/s CPU)")


if __name__ == "__main__":
    main()
