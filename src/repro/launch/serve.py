"""Serving launcher CLI: SAMP-quantized serving for BOTH workload types.

    # token-level continuous-batching generation (decode-capable archs)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --policy ffn --requests 8 --max-tokens 16

    # encoder micro-batch serving (the paper's CLUE-style workload)
    PYTHONPATH=src python -m repro.launch.serve --arch bert-base \
        --task tnews --policy ffn --requests 16

    # a saved PrecisionPlan, or an on-the-fly strategy search
    ... --plan plan.json
    ... --strategy greedy            # prefix_grid | greedy | latency_budget

    # compute backend for the quantized blocks (docs/architecture.md)
    ... --backend fused              # reference | fused | auto

    # input-adaptive precision (docs/adaptive-precision.md): per-cluster
    # calibration scales + request routing
    ... --clusters length:8,16      # length:<edges> | task:<labels> | kmeans:K

    # mesh-sharded serving: dp-way data parallel x tp-way tensor parallel
    # (docs/serving.md; needs dp*tp visible devices)
    ... --mesh 2,1

Instantiates the reduced config (this is the CPU-container path; on TPU the
same flow runs the full config), PTQ-calibrates on synthetic batches,
applies the requested precision — a named mode policy (``--policy``), a
saved declarative plan (``--plan plan.json``), or the winner of a search
strategy (``--strategy``, accuracy proxied by closeness to the float
forward, latency from the roofline model) — and serves a batch of random
requests through the continuous-batching decode engine (``--task lm``) or
the dynamic micro-batching encoder engine.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.calibration import synthetic_calibration_batches
from repro.core.plan import (PlanSet, PrecisionPlan, load_plan_or_planset,
                             plan_from_policy)
from repro.core.precision import make_policy
from repro.core.samp import SAMPEngine
from repro.data.pipeline import make_task
from repro.distributed.sharding import mesh_fingerprint
from repro.launch.cli import (add_serving_flags, parse_cluster_model,
                              resolve_task)
from repro.launch.mesh import make_serving_mesh
from repro.models import transformer as T
from repro.serve import (EncoderRequest, EncoderServeEngine, Request,
                         ServeEngine)
from repro.toolkit.registry import get_target
from repro.toolkit.targets import TARGET_FOR_TASK_KIND


def search_plan(cfg, eng: SAMPEngine, params, stats, strategy: str, *,
                seed: int = 0, seq: int = 32,
                max_latency=None, log=print) -> PrecisionPlan:
    """Pick a PrecisionPlan with a registered search strategy: accuracy is
    proxied by closeness of the quantized forward to the float forward on a
    synthetic batch (randomly initialized weights have no task accuracy);
    latency comes from the roofline backend."""
    from repro.toolkit.latency import RooflineBackend
    batch = synthetic_calibration_batches(cfg, num_batches=1, seq_len=seq,
                                          seed=seed)[0]
    ref, _ = T.forward(params, batch, cfg, eng.float_plan,
                       compute_dtype=jnp.float32)

    def eval_fn(qp, plan, pol):
        out, _ = T.forward(qp, batch, cfg, plan, eng.scheme,
                           compute_dtype=jnp.float32)
        return 1.0 - float(jnp.mean(jnp.abs(out - ref))
                           / (jnp.mean(jnp.abs(ref)) + 1e-9))

    latency_fn = RooflineBackend().bind(cfg, batch=8, seq=seq)
    kw = {}
    if strategy == "latency_budget":
        if max_latency is None:
            # default budget: 80% of the float roofline
            max_latency = 0.8 * latency_fn(None, None, eng.float_precision)
        kw["max_latency"] = max_latency
    points = eng.search(strategy, params, stats, eval_fn, latency_fn, **kw)
    recs = eng.recommend(points, max_latency=max_latency)
    chosen = next((r for r in recs if r.mode_name == "quant_ffn_only"),
                  recs[0] if recs else None)
    if chosen is None:
        log(f"[serve] strategy {strategy!r} found no quantized candidate; "
            f"serving float")
        return eng.float_precision
    log(f"[serve] strategy {strategy!r} chose {chosen.plan.describe()} "
        f"(speedup {chosen.recommendation.speedup:.3f}x)")
    return chosen.plan


def build_model(cfg, policy_name: str = "float", *, seed: int = 0,
                head=None, log=print, plan_file=None, strategy=None,
                max_latency=None):
    """Float init + optional SAMP PTQ (shared with
    benchmarks/serve_throughput.py — one build flow for everything that
    serves a synthetic-calibrated model). Precision comes from, in
    precedence order: a saved plan file, a search strategy, or the named
    mode policy. Returns ``(params, execution_plan, precision)`` — the
    PrecisionPlan rides along so engines can read per-layer KV-cache
    schemes (``precision.kv_schemes``)."""
    eng = SAMPEngine(cfg, float_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(seed), cfg,
                           eng.float_policy, head=head)
    precision = None
    if plan_file is not None:
        precision = PrecisionPlan.load(plan_file)
        log(f"[serve] loaded plan {plan_file}: {precision.describe()}")
    elif strategy is None:
        precision = plan_from_policy(make_policy(cfg, policy_name))
    if precision is not None and not (precision.num_quant_ffn
                                      or precision.num_quant_mha
                                      or precision.num_quant_kv):
        return params, eng.float_plan, precision
    batches = synthetic_calibration_batches(cfg, seed=seed)
    stats = eng.calibrate(params, batches, precision=precision)
    if strategy is not None and precision is None:
        precision = search_plan(cfg, eng, params, stats, strategy,
                                seed=seed, max_latency=max_latency, log=log)
        if not (precision.num_quant_ffn or precision.num_quant_mha
                or precision.num_quant_kv):
            return params, eng.float_plan, precision
    params, plan = eng.apply(params, stats, precision)
    log(f"[serve] applied SAMP plan: {precision.describe()}")
    return params, plan, precision


def build_routed_model(cfg, policy_name: str, cluster_model, *,
                       seed: int = 0, head=None, plan_file=None,
                       max_len: int = 64, log=print):
    """Input-adaptive build: fit the cluster model, calibrate
    cluster-conditional scales on a synthetic stream that covers every
    cluster, and assemble a :class:`~repro.adaptive.PlanRouter`.

    The PlanSet comes from ``--plan`` (a PlanSet file routes as-is; a
    single-plan file deploys uniformly) or from the named policy deployed
    uniformly — per-cluster *scales* still differ, which is the paper's
    self-adaptive point. Returns ``(router, default_entry)``; the default
    entry seeds the engine's constructor arguments.
    """
    from repro import adaptive

    eng = SAMPEngine(cfg, float_dtype="float32")
    params = T.init_params(jax.random.PRNGKey(seed), cfg,
                           eng.float_policy, head=head)
    batches, classes = adaptive.clustered_synthetic_batches(
        cfg, cluster_model, seed=seed, max_len=max_len)
    adaptive.fit_cluster_model(cluster_model, params, batches, cfg)
    stats = eng.calibrate(
        params, batches,
        clusters=adaptive.batch_clusters(cluster_model, batches,
                                         batch_classes=classes))
    cids = range(cluster_model.num_clusters)
    if plan_file is not None:
        loaded = load_plan_or_planset(plan_file)
        planset = (loaded if isinstance(loaded, PlanSet)
                   else PlanSet.uniform(loaded, cids))
        log(f"[serve] loaded {plan_file}: {planset.describe()}")
    else:
        planset = PlanSet.uniform(
            plan_from_policy(make_policy(cfg, policy_name)), cids)
    router = adaptive.build_router(cfg, params, planset, stats,
                                   cluster_model=cluster_model,
                                   scheme=eng.scheme,
                                   float_plan=eng.float_plan)
    log(f"[serve] {router.describe()}")
    return router, router.entry(planset.default)


def _traffic_class_for(router, i: int):
    """Synthetic traffic-class tag for request ``i``: TaskLabel routing is
    caller-declared, so the demo loop cycles the labels; content-routed
    models (length, kmeans) need no tag."""
    if router is None or not hasattr(router.model, "label_for"):
        return None
    return router.model.label_for(i % router.num_clusters)


def serve_decode(cfg, args) -> None:
    router = None
    if args.clusters is not None:
        model = parse_cluster_model(args.clusters)
        router, entry = build_routed_model(
            cfg, args.policy, model, seed=args.seed, plan_file=args.plan,
            max_len=args.max_len)
        params, plan, precision = entry.params, entry.plan, entry.precision
    else:
        params, plan, precision = build_model(
            cfg, args.policy, seed=args.seed, plan_file=args.plan,
            strategy=args.strategy, max_latency=args.max_latency)
    mesh = make_serving_mesh(args.mesh)
    server = ServeEngine(cfg, params, plan, batch_slots=args.slots,
                         max_len=args.max_len, seed=args.seed,
                         backend=args.backend, mesh=mesh,
                         page_size=args.page_size, kv_cache=args.kv_dtype,
                         precision=precision, router=router)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(2, 9))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        server.submit(Request(uid=i, prompt=prompt,
                              max_tokens=args.max_tokens,
                              temperature=args.temperature,
                              traffic_class=_traffic_class_for(router, i)))
    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    for req in sorted(done, key=lambda r: r.uid):
        print(f"  req{req.uid}: prompt={req.prompt} -> {req.output}")
    s = server.stats
    print(f"[serve] backend={server.runtime.backend.describe()} "
          f"mesh={mesh_fingerprint(server.runtime.mesh)}: "
          f"{s['retired']} requests, {s['tokens']} tokens in "
          f"{s['ticks']} ticks, {dt:.2f}s "
          f"({s['tokens'] / max(dt, 1e-9):.1f} tok/s CPU); "
          f"{s['runtime_traces']} compile(s) / "
          f"{s['runtime_executables']} executable(s)")
    if router is not None:
        print(f"[serve] clusters: {dict(router.requests_by_cluster)} "
              f"({router.active_plans} active plan(s))")


def serve_encoder(cfg, args) -> None:
    task = make_task(args.task, vocab_size=cfg.vocab_size,
                     seq_len=args.max_len)
    spec = get_target(TARGET_FOR_TASK_KIND[task.kind])
    head_kind = "ner" if spec.token_level else "cls"
    head = (head_kind, max(task.n_classes, 1))
    router = None
    if args.clusters is not None:
        model = parse_cluster_model(args.clusters)
        router, entry = build_routed_model(
            cfg, args.policy, model, seed=args.seed, head=head,
            plan_file=args.plan, max_len=args.max_len)
        params, plan = entry.params, entry.plan
    else:
        params, plan, _ = build_model(cfg, args.policy, seed=args.seed,
                                      head=head, plan_file=args.plan,
                                      strategy=args.strategy,
                                      max_latency=args.max_latency)
    mesh = make_serving_mesh(args.mesh)
    server = EncoderServeEngine(cfg, params, plan, target=spec,
                                max_batch=args.slots, max_len=args.max_len,
                                backend=args.backend, mesh=mesh,
                                router=router)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        n = int(rng.integers(4, args.max_len // 2))
        server.submit(EncoderRequest(
            uid=i, tokens=rng.integers(1, cfg.vocab_size, size=n).tolist(),
            traffic_class=_traffic_class_for(router, i)))
    t0 = time.perf_counter()
    server.run()                      # flush full + partial micro-batches
    dt = time.perf_counter() - t0
    s = server.stats
    print(f"[serve] task={args.task} target={spec.name} "
          f"backend={server.runtime.backend.describe()} "
          f"mesh={mesh_fingerprint(server.runtime.mesh)}: {s['retired']} "
          f"requests in {s['batches']} micro-batches, {dt:.2f}s "
          f"({s['retired'] / max(dt, 1e-9):.1f} req/s CPU); "
          f"{s['runtime_traces']} compile(s) / "
          f"{s['runtime_executables']} executable(s)")
    if router is not None:
        print(f"[serve] clusters: {dict(router.requests_by_cluster)} "
              f"({router.active_plans} active plan(s))")


def main():
    # deployment flags come from the shared launch.cli surface so this
    # entrypoint and launch/server.py cannot drift
    ap = add_serving_flags(argparse.ArgumentParser())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    args.task = resolve_task(cfg, args.task)
    if args.task == "lm":
        serve_decode(cfg, args)
    else:
        serve_encoder(cfg, args)


if __name__ == "__main__":
    main()
