"""The assigned input-shape grid + ShapeDtypeStruct input specs per cell.

Four shapes per LM architecture (40 cells total):

    train_4k      seq 4096,   global_batch 256   -> train_step
    prefill_32k   seq 32768,  global_batch 32    -> prefill (fwd + cache)
    decode_32k    seq 32768,  global_batch 128   -> serve_step (1 new token)
    long_500k     seq 524288, global_batch 1     -> serve_step, sub-quadratic
                                                    archs only

Skips (DESIGN.md §Arch-applicability): encoder-only archs (hubert) have no
decode; ``long_500k`` runs only where decode state is bounded (xlstm,
recurrentgemma, mixtral-SWA). ``input_specs`` returns weak-type-correct
ShapeDtypeStructs — nothing is allocated; the dry-run lowers against them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.precision import EncoderPolicy
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not) per the DESIGN.md skip rules."""
    cell = SHAPES[shape_name]
    if cell.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch: no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode is not sub-quadratic"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, cell: ShapeCell,
                compute_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for the raw model inputs of one cell."""
    B = cell.global_batch
    S = cell.seq_len
    if cell.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.frontend == "audio":
        return {"frames": _sds((B, S, cfg.frontend_dim), compute_dtype),
                "labels": _sds((B, S), jnp.int32)}
    batch = {}
    if cfg.frontend == "vision":
        P = cfg.num_prefix_embeds
        batch["prefix_embeds"] = _sds((B, P, cfg.frontend_dim), compute_dtype)
        batch["tokens"] = _sds((B, S - P), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if cfg.family == "bert":
        batch["segments"] = _sds((B, S), jnp.int32)
        batch["labels"] = _sds((B,), jnp.int32)
    return batch


def cache_specs(cfg: ArchConfig, plan, cell: ShapeCell,
                cache_dtype=jnp.bfloat16):
    """Abstract decode caches (eval_shape over the real constructor)."""
    return jax.eval_shape(
        lambda: T.init_caches(cfg, plan, cell.global_batch,
                              cell.seq_len, cache_dtype))


def params_specs(cfg: ArchConfig, policy: EncoderPolicy,
                 param_dtype=jnp.bfloat16, head=None):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, policy,
                              head=head, dtype=param_dtype))


def prefill_step(params, batch, caches, cfg: ArchConfig, plan,
                 scheme: T.QuantScheme = T.QuantScheme(), *,
                 constrain=lambda x, _t: x, chunk=T.DEFAULT_CHUNK,
                 compute_dtype=jnp.bfloat16):
    """Serving prefill: full-sequence forward writing the KV caches, last-
    token logits only (what a real prefill returns). Encoder-only archs
    return the full per-frame logits and no cache."""
    if not cfg.supports_decode:
        logits, _ = T.forward(params, batch, cfg, plan, scheme,
                              constrain=constrain, chunk=chunk,
                              compute_dtype=compute_dtype)
        return logits, None
    hidden, new_caches = T.forward(
        params, batch, cfg, plan, scheme, caches=caches, pos=0,
        constrain=constrain, chunk=chunk, compute_dtype=compute_dtype,
        return_hidden=True)
    logits = constrain(T.unembed(hidden[:, -1:], params, cfg), "logits")
    return logits, new_caches
