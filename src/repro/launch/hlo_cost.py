"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
model executing layers under ``lax.scan`` under-reports flops/bytes/
collective traffic by the trip count (verified empirically — a scan of 8
matmuls reports the flops of one). This module re-derives costs from
``compiled.as_text()`` with whiles multiplied out:

* flops: every ``dot`` op contributes 2 * prod(output dims) * prod(
  contracting dims) — the convention the 197 TFLOP/s peak uses. Dots inside
  fusions/calls are attributed through the call graph.
* bytes: every *top-level* instruction of an executed computation
  contributes output + operand bytes (fusions count as one pass — operands
  in, output out — matching how a fused TPU kernel touches HBM).
* collectives: output bytes + op counts per collective type.
* ``while`` trip counts parse from the loop condition's comparison constant
  (jax scans lower to ``lt(i, N)``); unknown conditions fall back to 1.

This is a structural model of the executable, not a simulator — exactly the
granularity a roofline needs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "token": 0,
                "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like f32[12,34]{1,0} or (f32[1,2], s32[3]) tuples
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls|body|condition|branch_computations|"
                     r"to_apply)=\{?%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str          # output type(s)
    rest: str              # full remainder of the line
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    symbols: dict          # name -> output type_str


_OPCODE = re.compile(r"^\(?[\w\[\],{}\s()]*?\)?\s*([a-z][\w\-]*)\(")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            # computation header: `%name (params) -> type {` or `ENTRY ...`
            header = s.lstrip("ENTRY ").strip()
            name = header.split("(")[0].strip().lstrip("%").strip()
            cur = Computation(name, [], {})
            comps[name] = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs: "<type> <opcode>(<operands>), attrs..."
        om = re.search(r"\s([a-z][\w\-]*)\(", rhs)
        opcode = om.group(1) if om else "unknown"
        # operand names inside the first (...) group
        paren = rhs[om.end() - 1:] if om else ""
        depth = 0
        args = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        operands = re.findall(r"%([\w.\-]+)", args)
        type_str = rhs[:om.start()] if om else rhs
        instr = Instr(name, opcode, type_str, rhs, operands)
        cur.instrs.append(instr)
        cur.symbols[name] = type_str
        # parameters also enter the symbol table via their declaration
    return comps


def _dot_flops(instr: Instr, symbols: dict) -> float:
    out_dims = _first_shape_dims(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if not m or not instr.operands:
        return 0.0
    lhs_type = symbols.get(instr.operands[0], "")
    lhs_dims = _first_shape_dims(lhs_type)
    contract = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            contract *= lhs_dims[int(d)]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contract


def _trip_count(cond: Computation) -> int:
    """jax scans lower to `compare(i, const), direction=LT`."""
    consts = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.rest)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.opcode == "compare" and "direction=LT" in ins.rest:
            for op in ins.operands:
                if op in consts:
                    return max(consts[op], 1)
    ints = [v for v in consts.values() if v > 0]
    return max(ints) if ints else 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"bytes": 0.0,
                                                     "count": 0.0}))

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.collective_bytes += other.collective_bytes * times
        for k, v in other.per_collective.items():
            self.per_collective[k]["bytes"] += v["bytes"] * times
            self.per_collective[k]["count"] += v["count"] * times


def analyze_hlo(text: str) -> dict:
    comps = parse_hlo(text)
    memo: dict[str, Cost] = {}

    def cost_of(name: str, depth=0) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        c = Cost()
        if comp is None or depth > 64:
            memo[name] = c
            return c
        memo[name] = c        # break cycles defensively
        for ins in comp.instrs:
            if ins.opcode == "dot":
                c.flops += _dot_flops(ins, comp.symbols)
                c.bytes += _instr_bytes(ins, comp.symbols)
            elif ins.opcode == "while":
                called = _CALLED.findall(ins.rest)
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    c.add(cost_of(body, depth + 1), trips)
            elif ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if m and m.group(1) in comps:
                    sub = cost_of(m.group(1), depth + 1)
                    c.flops += sub.flops
                    # fusion = one pass, but parameters consumed only via
                    # dynamic-slice/gather are charged the slice, and
                    # in-place dynamic-update-slice outputs are charged the
                    # update region (scan weight stacks / KV caches!)
                    c.bytes += _fusion_bytes(ins, comps[m.group(1)],
                                             comp.symbols)
                else:
                    c.bytes += _instr_bytes(ins, comp.symbols)
            elif ins.opcode in ("call", "custom-call"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                if m:
                    c.add(cost_of(m.group(1), depth + 1))
                c.bytes += _instr_bytes(ins, comp.symbols)
            elif ins.opcode == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     ins.rest)
                if branches:
                    subs = [cost_of(b.strip().lstrip("%"), depth + 1)
                            for b in branches.group(1).split(",")]
                    if subs:
                        biggest = max(subs, key=lambda s: s.flops + s.bytes)
                        c.add(biggest)
            else:
                base = ins.opcode.replace("-start", "")
                if base in _COLLECTIVES:
                    nb = _shape_bytes(ins.type_str)
                    c.collective_bytes += nb
                    c.per_collective[base]["bytes"] += nb
                    c.per_collective[base]["count"] += 1
                    c.bytes += _instr_bytes(ins, comp.symbols)
                elif ins.opcode not in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast"):
                    c.bytes += _instr_bytes(ins, comp.symbols)
        memo[name] = c
        return c

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split("(")[0].replace("ENTRY", "").strip() \
                .lstrip("%").strip()
            break
    if entry is None:
        # fall back: the largest computation
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    total = cost_of(entry)
    return {"flops": total.flops, "bytes": total.bytes,
            "collective_bytes": total.collective_bytes,
            "collectives": {k: dict(v)
                            for k, v in total.per_collective.items()}}


_SLICE_OPS = ("dynamic-slice", "gather")

# pure-elementwise ops fuse into their producers on TPU: charge the output
# write only (the read was someone else's write)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "select",
    "compare", "convert", "exponential", "exponential-minus-one", "tanh",
    "negate", "abs", "power", "rsqrt", "sqrt", "log", "log-plus-one", "and",
    "or", "not", "xor", "clamp", "round-nearest-even", "round-nearest-afz",
    "floor", "ceil", "sign", "cosine", "sine", "is-finite", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "rem",
    "broadcast", "iota", "reshape", "transpose", "copy", "pad", "slice",
    "reverse", "concatenate", "map", "logistic", "cbrt",
}


def _dtype_width(type_str: str) -> int:
    m = _SHAPE.search(type_str)
    if not m:
        return 4
    return _DTYPE_BYTES.get(m.group(1), 4)


def _instr_bytes(ins: Instr, symbols: dict) -> float:
    out = _shape_bytes(ins.type_str)
    if ins.opcode in _SLICE_OPS:
        # reads only the slice from HBM, not the whole operand
        return float(2 * out)
    if ins.opcode == "dynamic-update-slice":
        # in-place update: traffic = read+write of the update region
        upd = (_shape_bytes(symbols.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else out)
        return float(2 * upd)
    if ins.opcode == "convert" and ins.operands:
        # TPU-native projection: the CPU backend widens int8/bf16 operands
        # to f32 for dots it cannot emulate natively; on the MXU these
        # converts do not exist. Charge the source width.
        return float(_shape_bytes(symbols.get(ins.operands[0], "")) or out)
    if ins.opcode in _ELEMENTWISE:
        return float(out)
    opnds = sum(_shape_bytes(symbols.get(o, "")) for o in ins.operands)
    return float(out + opnds)


_VIEW_OPS = {"parameter", "convert", "bitcast", "constant", "tuple",
             "get-tuple-element"}
_LAYOUT_OPS = _VIEW_OPS | {"copy", "transpose", "reshape", "broadcast"}


def _fusion_bytes(ins: Instr, called: Computation, symbols: dict) -> float:
    """HBM traffic of one fused kernel: each fusion parameter is charged by
    HOW the fused computation reads it (slice vs full), and an in-place
    dynamic-update-slice root is charged the update region only.

    dtype-cast-only fusions are elided: the CPU backend emulates bf16 dots
    by converting operands to f32 — materializations that do not exist on
    the TPU's native-bf16 MXU path (the projection target). Pure layout
    fusions (transpose/copy) charge one output pass."""
    opcodes = {i.opcode for i in called.instrs}
    if opcodes <= _VIEW_OPS:
        return 0.0
    if opcodes <= _LAYOUT_OPS:
        return float(_shape_bytes(ins.type_str))
    # TPU-native dtype projection for the fusion OUTPUT: when the fused
    # computation only widens its inputs (e.g. s8/bf16 -> f32 dequant or
    # CPU dot-emulation casts), charge the output at the narrowest input
    # width — the MXU consumes the narrow dtype directly.
    in_width = min((_dtype_width(called.symbols.get(i.name, ""))
                    for i in called.instrs if i.opcode == "parameter"),
                   default=4)
    out_width = _dtype_width(ins.type_str)
    width_scale = min(in_width, out_width) / max(out_width, 1)
    params: dict[str, int] = {}
    for i in called.instrs:
        if i.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.rest)
            if m:
                params[i.name] = int(m.group(1))
    # resolve pure-view/cast chains so a dus of bitcast(param) still counts
    # as an in-place update of the param
    alias: dict[str, str] = {p: p for p in params}
    for i in called.instrs:
        if i.opcode in ("bitcast", "reshape", "copy", "convert",
                        "transpose") and i.operands:
            src = alias.get(i.operands[0])
            if src is not None:
                alias[i.name] = src
    consumers: dict[str, list[Instr]] = {}
    for i in called.instrs:
        for o in i.operands:
            root = alias.get(o)
            if root is not None:
                consumers.setdefault(root, []).append(i)
    total = 0.0
    in_place_updated = False
    for pname, idx in params.items():
        outer = (ins.operands[idx] if idx < len(ins.operands) else None)
        full = _shape_bytes(symbols.get(outer, "")) if outer else \
            _shape_bytes(called.symbols.get(pname, ""))
        cons = [ci for ci in consumers.get(pname, [])
                if ci.opcode not in ("bitcast", "reshape", "copy", "convert",
                                     "transpose")]
        dus_cons = [ci for ci in cons
                    if ci.opcode == "dynamic-update-slice"
                    and ci.operands and alias.get(ci.operands[0]) == pname]
        if cons and all(ci.opcode in _SLICE_OPS for ci in cons):
            total += sum(_shape_bytes(ci.type_str) for ci in cons)
        elif cons and len(dus_cons) == len(cons):
            # parameter is an in-place updated buffer: traffic = region
            in_place_updated = True
            total += sum(_shape_bytes(called.symbols.get(
                ci.operands[1], ci.type_str)) if len(ci.operands) > 1
                else _shape_bytes(ci.type_str) for ci in cons)
        else:
            total += full
    # output: an in-place-updated buffer flowing to the root (possibly
    # through converts/copies) writes only the update region
    dus_regions = [
        _shape_bytes(called.symbols.get(ci.operands[1], ci.type_str))
        if len(ci.operands) > 1 else _shape_bytes(ci.type_str)
        for ci in called.instrs if ci.opcode == "dynamic-update-slice"]
    if in_place_updated and dus_regions:
        total += sum(dus_regions)
    else:
        total += _shape_bytes(ins.type_str) * width_scale
    return total
