"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 256 --ckpt /tmp/run1

Local runs use the reduced() config on the host mesh; ``--full`` selects the
production config (real-hardware path). Resumes automatically from the
newest checkpoint in --ckpt; survives kill-at-any-step.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.precision import EncoderPolicy
from repro.data import get_batch, make_task
from repro.launch.mesh import make_host_mesh
from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="production config (default: reduced smoke config)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    policy = EncoderPolicy.full_float(cfg.num_layers, "bfloat16")
    mesh = make_host_mesh(model=args.mesh_model) \
        if len(jax.devices()) > 1 else None
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt,
                       grad_accum=args.grad_accum, remat=True,
                       compute_dtype=args.dtype,
                       compress_pod_grads=args.compress_pod_grads)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=min(20, args.steps // 10),
                                   total=args.steps))
    trainer = Trainer(cfg, policy, mesh=mesh, optimizer=opt, tcfg=tcfg)
    state = trainer.init_state(jax.random.PRNGKey(args.seed),
                               dtype=jnp.dtype(args.dtype))
    task = make_task("lm", vocab_size=cfg.vocab_size, seq_len=args.seq)

    def next_batch(i):
        b = get_batch(task, i, args.batch)
        if cfg.frontend == "audio":
            g = jax.random.PRNGKey(i)
            frames = jax.random.normal(
                g, (args.batch, args.seq, cfg.frontend_dim),
                jnp.dtype(args.dtype))
            return {"frames": frames,
                    "labels": jnp.asarray(b["tokens"] % cfg.vocab_size)}
        return {k: jnp.asarray(v) for k, v in b.items()}

    trainer.fit(state, next_batch)
    print(f"[train] done: {args.steps} steps of {args.arch}"
          f"{' (reduced)' if not args.full else ''}")


if __name__ == "__main__":
    main()
