"""SAMP quickstart: the paper's full workflow in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. fine-tune a (reduced) BERT classifier on a synthetic CLUE-like task
2. calibrate activation ranges on a handful of batches (min-max, paper §4.1)
3. sweep the (mode, k) mixed-precision grid — accuracy measured, latency
   from the TPU roofline model (wall-clock on real hardware)
4. let the accuracy-decay-aware allocator (Algorithm 1) pick the tradeoff
5. run inference with the recommended mixed-precision configuration
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

import jax
import jax.numpy as jnp

from benchmarks.latency_model import encoder_latency
from repro.configs import get_config
from repro.core.samp import SAMPEngine
from repro.data import eval_accuracy, get_batch, make_task
from repro.models import transformer as T
from repro.train import AdamW, TrainConfig, Trainer
from repro.train.trainer import TrainState

N_CLASSES, SEQ = 15, 32

# -- 1. fine-tune ------------------------------------------------------------
cfg = get_config("bert-base").reduced().replace(num_layers=12)
task = make_task("tnews", vocab_size=cfg.vocab_size, seq_len=SEQ)
eng = SAMPEngine(cfg, float_dtype="float32")
trainer = Trainer(cfg, eng.float_policy, optimizer=AdamW(lr=2e-3),
                  tcfg=TrainConfig(steps=120, log_every=40,
                                   compute_dtype="float32", remat=False),
                  head=("cls", N_CLASSES))
state = trainer.init_state(jax.random.PRNGKey(0))
step = trainer.make_step()
for i in range(trainer.tcfg.steps):
    batch = {k: jnp.asarray(v) for k, v in get_batch(task, i, 32).items()}
    p, o, e, m = step(state.params, state.opt_state, state.err_state, batch)
    state = TrainState(p, o, e)
    if (i + 1) % 40 == 0:
        print(f"  ft step {i + 1}: loss={float(m['loss']):.3f}")
params = state.params

# -- 2. calibrate --------------------------------------------------------------
calib = [{"tokens": jnp.asarray(b["tokens"]),
          "segments": jnp.asarray(b["segments"])}
         for b in (get_batch(task, 999 + i, 16) for i in range(4))]
stats = eng.calibrate(params, calib)
print(f"calibrated {sum(len(v) for v in stats.values())} activation sites")


# -- 3. sweep -------------------------------------------------------------------
def predict(plan, qp):
    @jax.jit
    def f(tokens, segments):
        h, _ = T.forward(qp, {"tokens": tokens, "segments": segments},
                         cfg, plan, compute_dtype=jnp.float32)
        return jnp.argmax(T.apply_head(h, qp, "cls"), -1)
    return lambda b: f(jnp.asarray(b["tokens"]), jnp.asarray(b["segments"]))


points = eng.sweep(
    params, stats,
    eval_fn=lambda qp, plan, pol: eval_accuracy(predict(plan, qp), task,
                                                batches=3, batch_size=64),
    latency_fn=lambda qp, plan, pol: encoder_latency(cfg, pol, batch=32,
                                                     seq=SEQ),
    stride=4)
base = points[0]
print("\nmode             k  accuracy  speedup")
for pt in points:
    print(f"{pt.mode_name:15s} {pt.k:2d}  {pt.accuracy:.4f}    "
          f"{base.latency / pt.latency:.3f}x")

# -- 4. recommend ---------------------------------------------------------------
for rec in eng.recommend(points):
    r = rec.recommendation
    print(f"\nSAMP recommends [{rec.mode_name}]: k={rec.point.k} "
          f"accuracy={r.accuracy:.4f} (drop {r.accuracy_drop:+.4f}) "
          f"speedup={r.speedup:.3f}x")

# -- 5. deploy the quant-ffn-only recommendation ---------------------------------
chosen = next(r for r in eng.recommend(points)
              if r.mode_name == "quant_ffn_only")
qparams, qplan = eng.apply(params, stats, chosen.point.policy)
acc = eval_accuracy(predict(qplan, qparams), task, batches=3, batch_size=64)
print(f"\ndeployed {chosen.point.policy.describe()} -> dev accuracy {acc:.4f}")
