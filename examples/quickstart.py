"""SAMP quickstart: the paper's full workflow through the toolkit facade.

    PYTHONPATH=src python examples/quickstart.py

1. fine-tune a (reduced) BERT classifier on a synthetic CLUE-like task
2. calibrate activation ranges on a handful of batches (min-max, paper §4.1)
3. sweep the (mode, k) mixed-precision grid — accuracy measured, latency
   from the TPU roofline backend (swap latency="wallclock" on real hardware)
4. let the accuracy-decay-aware allocator (Algorithm 1) pick the tradeoff
5. deploy the quant-ffn-only recommendation — and save it as an artifact
   bundle that reloads without re-calibration (SAMP.load)
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro import SAMP
from repro.configs import get_config

# -- 1. fine-tune ------------------------------------------------------------
cfg = get_config("bert-base").reduced().replace(num_layers=12)
samp = SAMP.from_config(cfg, task="tnews", seq_len=32,
                        float_dtype="float32", latency="roofline")
samp.finetune(steps=120, log_every=40)

# -- 2. calibrate ------------------------------------------------------------
stats = samp.calibrate(num_batches=4, batch_size=16)
print(f"calibrated {sum(len(v) for v in stats.values())} activation sites")

# -- 3/4/5. sweep -> recommend -> deploy, one call ---------------------------
report = samp.autotune(strategy="prefix_grid", stride=4, eval_batches=3,
                       eval_batch_size=64, prefer="quant_ffn_only",
                       save_to="/tmp/samp_tnews_bundle")
print("\n" + report.table())
print("\n" + report.summary())
print(f"\ndeployed {report.plan.describe()} "
      f"-> dev accuracy {report.accuracy:.4f}")
print(f"artifact bundle: {report.artifact_path} "
      f"(reload with SAMP.load -- no re-calibration)")

# the chosen PrecisionPlan is itself a deployable, serializable artifact:
report.plan.save("/tmp/samp_tnews_plan.json")
print("precision plan: /tmp/samp_tnews_plan.json "
      f"(fingerprint {report.plan.fingerprint()[:12]}; lint with "
      f"python -m repro.toolkit.plan_lint)")
