"""End-to-end training driver: a ~125M-class LM for a few hundred steps with
checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m] \
        [--steps 300] [--ckpt /tmp/lm_run]

Kill it at any step and re-run the same command — it resumes from the newest
atomic checkpoint and fast-forwards the counter-indexed data stream. On a
multi-device host it shards with the FSDP+TP rules automatically.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.precision import EncoderPolicy
from repro.data import get_batch, make_task
from repro.launch.mesh import make_host_mesh
from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-125m")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--ckpt", default="/tmp/repro_lm_run")
ap.add_argument("--full", action="store_true",
                help="full config (TPU path); default = reduced smoke config")
args = ap.parse_args()

cfg = get_config(args.arch)
if not args.full:
    cfg = cfg.reduced()
policy = EncoderPolicy.full_float(cfg.num_layers, "float32")
mesh = make_host_mesh() if len(jax.devices()) > 1 else None
trainer = Trainer(
    cfg, policy, mesh=mesh,
    optimizer=AdamW(lr=cosine_schedule(3e-3, warmup=20, total=args.steps)),
    tcfg=TrainConfig(steps=args.steps, log_every=20, checkpoint_every=50,
                     checkpoint_dir=args.ckpt, compute_dtype="float32",
                     remat=True))
state = trainer.init_state(jax.random.PRNGKey(0))
task = make_task("lm", vocab_size=cfg.vocab_size, seq_len=args.seq)
state = trainer.fit(
    state, lambda i: {k: jnp.asarray(v)
                      for k, v in get_batch(task, i, args.batch).items()})
print(f"done: {args.steps} steps of {args.arch}"
      f"{'' if args.full else ' (reduced)'}; checkpoints in {args.ckpt}")
