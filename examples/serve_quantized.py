"""Serve a SAMP-quantized LM with continuous batching.

    PYTHONPATH=src python examples/serve_quantized.py \
        [--arch qwen2-0.5b] [--policy ffn] [--requests 8]

Builds the (reduced) model, PTQ-calibrates it, applies the requested SAMP
policy (default: Quant-FFN-Only on all layers — the paper's preferred mode),
and streams a mixed batch of generation requests through the token-level
continuous-batching engine. Requests of different prompt lengths prefill
and decode side-by-side in the same compiled step.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.precision import make_policy
from repro.core.samp import SAMPEngine
from repro.models import transformer as T
from repro.serve import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b")
ap.add_argument("--policy", default="ffn", help="float | ffn[K] | full[K]")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--max-tokens", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
eng = SAMPEngine(cfg, float_dtype="float32")
params = T.init_params(jax.random.PRNGKey(0), cfg, eng.float_policy)

policy = make_policy(cfg, args.policy, "float32")
if policy.num_quant_ffn or policy.num_quant_mha:
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 32),
                                           0, cfg.vocab_size)}
             for i in range(4)]
    stats = eng.calibrate(params, calib)
    params, plan = eng.apply(params, stats, policy)
    print(f"SAMP policy applied: {policy.describe()}")
else:
    plan = eng.float_plan

server = ServeEngine(cfg, params, plan, batch_slots=args.slots, max_len=128)
rng = np.random.default_rng(0)
for i in range(args.requests):
    prompt = rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(2, 10))).tolist()
    server.submit(Request(uid=i, prompt=prompt, max_tokens=args.max_tokens))

t0 = time.perf_counter()
done = server.run()
dt = time.perf_counter() - t0
for req in sorted(done, key=lambda r: r.uid):
    print(f"  req{req.uid}: {len(req.prompt)}-token prompt -> {req.output}")
s = server.stats
print(f"{s['retired']} requests / {s['tokens']} tokens / {s['ticks']} ticks "
      f"in {dt:.1f}s ({s['tokens'] / max(dt, 1e-9):.1f} tok/s on CPU)")
