"""Serve a SAMP-quantized LM from a saved PrecisionPlan, via the toolkit.

    PYTHONPATH=src python examples/serve_quantized.py \
        [--arch qwen2-0.5b] [--plan plan.json] [--requests 8] [--bundle DIR]

The deployment flow is plan-first: precision is a declarative
``plan.json`` (write one by hand, with ``PrecisionPlan.save``, or from
``SAMP.autotune(...).plan.save(...)``) — not a policy constructed in code.
This script

1. loads the plan (``--plan``; without one it writes a demo plan first:
   Quant-FFN-Only on every layer — the paper's preferred mode — with a
   percentile calibrator on the FFN input block),
2. lints it against the target architecture,
3. PTQ-calibrates through the SAMP facade honoring the plan's per-block
   calibrator choices, applies the plan, and saves an artifact bundle,
4. RELOADS the bundle (no re-calibration), checks the plan fingerprint
   survived byte-identically, and streams a mixed batch of generation
   requests through the token-level continuous-batching engine.
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro import SAMP, PrecisionPlan
from repro.configs import get_config
from repro.core.plan import LayerPlan, QuantSpec
from repro.serve import Request
from repro.toolkit.plan_lint import lint

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b")
ap.add_argument("--plan", default=None,
                help="saved PrecisionPlan JSON (default: write + use a "
                     "demo ffn-only plan)")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--max-tokens", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--bundle", default=None,
                help="artifact dir (default: a temp dir)")
ap.add_argument("--backend", default="reference",
                choices=("reference", "fused", "auto"),
                help="compute backend for the quantized blocks "
                     "(docs/architecture.md)")
args = ap.parse_args()

cfg = get_config(args.arch).reduced()

# -- 1. the plan file ---------------------------------------------------------
if args.plan is None:
    ffn_spec = QuantSpec(weight="int8_per_channel", act="int8_per_tensor",
                         calibrator="percentile")
    demo = PrecisionPlan.uniform(
        cfg.num_layers, LayerPlan(ffn_in=ffn_spec, ffn_out=ffn_spec),
        float_dtype="float32")
    args.plan = str(pathlib.Path(tempfile.mkdtemp(prefix="samp_plan_"))
                    / "plan.json")
    demo.save(args.plan)
    print(f"wrote demo plan to {args.plan}")

# -- 2. lint, then load -------------------------------------------------------
plan = lint(args.plan, num_layers=cfg.num_layers)

# -- 3. calibrate + apply + bundle -------------------------------------------
samp = SAMP.from_config(cfg, task="lm", seq_len=32, float_dtype="float32",
                        backend=args.backend)
samp.pipeline.init_params(jax.random.PRNGKey(0))

if plan.num_quant_ffn or plan.num_quant_mha:
    samp.calibrate(num_batches=4, batch_size=2, precision=plan)
    samp.apply(plan)
    print(f"SAMP plan applied: {plan.describe()}")
    bundle = args.bundle or tempfile.mkdtemp(prefix="samp_bundle_")
    samp.save(bundle)
    # deploy path: no calibration batches; the compute backend is chosen
    # at load time (it is a deployment property, not part of the bundle)
    samp = SAMP.load(bundle, backend=args.backend)
    reloaded = samp.current.precision
    assert reloaded.fingerprint() == plan.fingerprint(), "plan drifted!"
    print(f"reloaded artifact bundle from {bundle} "
          f"(plan fingerprint {reloaded.fingerprint()[:12]} intact)")

# -- 4. serve -----------------------------------------------------------------
server = samp.serve(batch_slots=args.slots, max_len=128)
print(f"serving on compute backend: {server.runtime.backend.describe()}")
rng = np.random.default_rng(0)
for i in range(args.requests):
    prompt = rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(2, 10))).tolist()
    server.submit(Request(uid=i, prompt=prompt, max_tokens=args.max_tokens))

t0 = time.perf_counter()
done = server.run()
dt = time.perf_counter() - t0
for req in sorted(done, key=lambda r: r.uid):
    print(f"  req{req.uid}: {len(req.prompt)}-token prompt -> {req.output}")
s = server.stats
print(f"{s['retired']} requests / {s['tokens']} tokens / {s['ticks']} ticks "
      f"in {dt:.1f}s ({s['tokens'] / max(dt, 1e-9):.1f} tok/s on CPU)")
