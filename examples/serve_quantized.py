"""Serve a SAMP-quantized LM with continuous batching, via the toolkit.

    PYTHONPATH=src python examples/serve_quantized.py \
        [--arch qwen2-0.5b] [--policy ffn] [--requests 8] [--bundle DIR]

Builds the (reduced) model through the SAMP facade, PTQ-calibrates it,
applies the requested policy (default: Quant-FFN-Only on all layers — the
paper's preferred mode), saves the result as a quantized artifact bundle,
then RELOADS the bundle (no re-calibration) and streams a mixed batch of
generation requests through the token-level continuous-batching engine.
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro import SAMP
from repro.configs import get_config
from repro.core.precision import make_policy
from repro.serve import Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b")
ap.add_argument("--policy", default="ffn", help="float | ffn[K] | full[K]")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--max-tokens", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--bundle", default=None,
                help="artifact dir (default: a temp dir)")
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
samp = SAMP.from_config(cfg, task="lm", seq_len=32, float_dtype="float32")
samp.pipeline.init_params(jax.random.PRNGKey(0))

policy = make_policy(cfg, args.policy, "float32")
if policy.num_quant_ffn or policy.num_quant_mha:
    samp.calibrate(num_batches=4, batch_size=2)
    samp.apply(policy)
    print(f"SAMP policy applied: {policy.describe()}")
    bundle = args.bundle or tempfile.mkdtemp(prefix="samp_bundle_")
    samp.save(bundle)
    samp = SAMP.load(bundle)        # deploy path: no calibration batches
    print(f"reloaded artifact bundle from {bundle}")

server = samp.serve(batch_slots=args.slots, max_len=128)
rng = np.random.default_rng(0)
for i in range(args.requests):
    prompt = rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(2, 10))).tolist()
    server.submit(Request(uid=i, prompt=prompt, max_tokens=args.max_tokens))

t0 = time.perf_counter()
done = server.run()
dt = time.perf_counter() - t0
for req in sorted(done, key=lambda r: r.uid):
    print(f"  req{req.uid}: {len(req.prompt)}-token prompt -> {req.output}")
s = server.stats
print(f"{s['retired']} requests / {s['tokens']} tokens / {s['ticks']} ticks "
      f"in {dt:.1f}s ({s['tokens'] / max(dt, 1e-9):.1f} tok/s on CPU)")
